"""Unified metrics registry (repro.obs).

Before this module every subsystem grew its own ``stats()`` dict —
``hostmem.metrics.collect``, ``engine.stats``, ``Server.latency_stats``,
``ChameleonRuntime.stats`` — and every consumer (benchmarks, the launch
CLIs, dashboards) stitched them together ad hoc.  The registry gives
them one schema:

  * **counters** — monotonically increasing ints (``counter(name)``);
  * **gauges** — last-write-wins floats with a bounded ``(t, value)``
    ring series per gauge (``gauge(name, v)``), so a snapshot carries
    recent history without unbounded growth;
  * **providers** — named callables returning a stats dict, evaluated
    lazily at snapshot time.  Subsystems register their existing
    ``stats()`` methods (``register_provider("hostmem", tier.stats)``)
    and the registry never copies their internals between snapshots.

``snapshot()`` returns one JSON-safe dict; ``write_jsonl`` appends it to
a file — the periodic snapshot writer the trainer drives on a step
cadence and the nightly workflow uploads as an artifact.  A provider
that raises contributes ``{"error": ...}`` instead of killing the
snapshot (observability must not take down the observed).
"""
from __future__ import annotations

import collections
import json
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.obs.tracer import _json_safe

SNAPSHOT_KEYS = ("time", "seq", "counters", "gauges", "series", "providers")


class MetricsRegistry:
    def __init__(self, series_len: int = 256):
        self.series_len = int(series_len)
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, float] = {}
        self._series: Dict[str, Deque[Tuple[float, float]]] = {}
        self._providers: Dict[str, Callable[[], dict]] = {}
        self._lock = threading.Lock()
        self._seq = 0

    # ----------------------------------------------------------- recording
    def counter(self, name: str, inc: int = 1) -> int:
        with self._lock:
            v = self._counters.get(name, 0) + int(inc)
            self._counters[name] = v
            return v

    def gauge(self, name: str, value: float, t: Optional[float] = None) -> None:
        with self._lock:
            self._gauges[name] = float(value)
            s = self._series.get(name)
            if s is None:
                s = self._series[name] = collections.deque(
                    maxlen=self.series_len)
            s.append((time.time() if t is None else t, float(value)))

    def series(self, name: str) -> List[Tuple[float, float]]:
        with self._lock:
            return list(self._series.get(name, ()))

    # ----------------------------------------------------------- providers
    def register_provider(self, name: str, fn: Callable[[], dict]) -> None:
        """Attach (or replace — a re-built subsystem re-registers under
        the same name) a stats provider."""
        with self._lock:
            self._providers[name] = fn

    def unregister_provider(self, name: str) -> None:
        with self._lock:
            self._providers.pop(name, None)

    def provider_names(self) -> List[str]:
        with self._lock:
            return sorted(self._providers)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> dict:
        with self._lock:
            self._seq += 1
            out = {
                "time": time.time(),
                "seq": self._seq,
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "series": {k: list(v) for k, v in self._series.items()},
                "providers": {},
            }
            providers = list(self._providers.items())
        for name, fn in providers:
            try:
                out["providers"][name] = _json_safe(fn())
            except Exception as e:  # noqa: BLE001 — never kill the snapshot
                out["providers"][name] = {"error": repr(e)}
        return out

    def write_jsonl(self, path: str, snap: Optional[dict] = None) -> dict:
        """Append one snapshot as a JSONL line."""
        snap = snap if snap is not None else self.snapshot()
        with open(path, "a") as f:
            f.write(json.dumps(_json_safe(snap)) + "\n")
        return snap

    # --------------------------------------------------------------- admin
    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._series.clear()
            self._providers.clear()
            self._seq = 0

    def stats(self) -> dict:
        with self._lock:
            return {"counters": len(self._counters),
                    "gauges": len(self._gauges),
                    "providers": len(self._providers),
                    "snapshots": self._seq}
