"""repro.obs — always-on tracing, unified metrics, and the drift audit log.

Three pillars (ISSUE 6), all bounded-memory so they stay enabled in
production, matching the monitoring hot path's "cheap enough to leave
on" bar:

  * :class:`SpanTracer` — ring-buffered span recorder over five fixed
    lanes (``compute``, ``policy_swap``, ``kv_spill``, ``checkpoint``,
    ``adapt``), exported as Chrome trace-event JSON
    (:func:`export_chrome_trace`) and reduced to a per-iteration
    **overlap-efficiency** metric (:mod:`repro.obs.overlap`);
  * :class:`MetricsRegistry` — one counter/gauge/provider registry the
    scattered ``stats()`` dicts register into, with a JSONL snapshot
    writer;
  * :class:`AuditLog` — structured drift-decision events (classify /
    demote / apply / store-put / stage transitions);
  * :class:`MemoryLedger` — per-iteration realized HBM occupancy replay
    from observed swap/spill/checkpoint events: realized peak + top-k
    attribution, the predicted-vs-realized Simulator scoreboard,
    budget-headroom feedback for the health FSM, byte-conservation leak
    detection, and the :data:`LEDGER_TRACKS` Perfetto counter tracks.

Process-wide defaults are exposed through :func:`tracer`,
:func:`metrics`, :func:`audit`, and :func:`ledger` — subsystems record
into them without plumbing an object through every constructor, exactly
like a logging root logger.  Tests that need isolation swap them with
:func:`set_tracer` / :func:`set_audit` / :func:`set_metrics` /
:func:`set_ledger` (each returns the previous instance) or simply
``clear()`` the defaults.
"""
from __future__ import annotations

from repro.obs.audit import AuditLog
from repro.obs.memledger import LEDGER_TRACKS, MemoryLedger
from repro.obs.metrics import MetricsRegistry, SNAPSHOT_KEYS
from repro.obs.overlap import (interval_union, overlap_efficiency,
                               window_efficiency)
from repro.obs.tracer import (LANE_ADAPT, LANE_CHECKPOINT, LANE_COMPUTE,
                              LANE_ID, LANE_KV_SPILL, LANE_POLICY_SWAP,
                              LANES, TRANSFER_LANES, SpanTracer,
                              chrome_trace_events, export_chrome_trace)
from repro.obs.validate import validate_chrome_trace, validate_metrics_jsonl

__all__ = [
    "AuditLog", "MetricsRegistry", "SpanTracer", "SNAPSHOT_KEYS",
    "MemoryLedger", "LEDGER_TRACKS",
    "LANES", "LANE_ID", "LANE_COMPUTE", "LANE_POLICY_SWAP", "LANE_KV_SPILL",
    "LANE_CHECKPOINT", "LANE_ADAPT", "TRANSFER_LANES",
    "chrome_trace_events", "export_chrome_trace",
    "interval_union", "overlap_efficiency", "window_efficiency",
    "validate_chrome_trace", "validate_metrics_jsonl",
    "tracer", "metrics", "audit", "ledger",
    "set_tracer", "set_metrics", "set_audit", "set_ledger",
]

_tracer = SpanTracer()
_metrics = MetricsRegistry()
_audit = AuditLog()
_ledger = MemoryLedger()


def tracer() -> SpanTracer:
    """The process-wide default tracer (always on)."""
    return _tracer


def metrics() -> MetricsRegistry:
    """The process-wide default metrics registry."""
    return _metrics


def audit() -> AuditLog:
    """The process-wide default drift audit log."""
    return _audit


def ledger() -> MemoryLedger:
    """The process-wide default memory ledger (always on)."""
    return _ledger


def set_tracer(t: SpanTracer) -> SpanTracer:
    global _tracer
    old, _tracer = _tracer, t
    return old


def set_metrics(m: MetricsRegistry) -> MetricsRegistry:
    global _metrics
    old, _metrics = _metrics, m
    return old


def set_audit(a: AuditLog) -> AuditLog:
    global _audit
    old, _audit = _audit, a
    return old


def set_ledger(l: MemoryLedger) -> MemoryLedger:
    global _ledger
    old, _ledger = _ledger, l
    return old
