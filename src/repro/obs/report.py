"""Post-mortem report builder (``python -m repro.obs.report``).

Turns a run's exported artifacts — Chrome trace JSON, metrics JSONL,
audit-event JSONL — into one markdown (and optionally JSON) post-mortem:
peak trajectory and the predicted-vs-realized scoreboard, overlap
efficiency, drift-tier decisions, fault / degradation-ladder / health
events, and leak suspects.  The nightly workflow also uses it as a
release gate::

    PYTHONPATH=src python -m repro.obs.report \
        --trace run.trace.json --metrics run.metrics.jsonl \
        --audit run.audit.jsonl --out postmortem.md \
        --json postmortem.json --check-peak-error 0.10

``--check-peak-error FRAC`` exits non-zero when any scored iteration's
|realized - projected| / projected exceeds FRAC — or when no iteration
was scored at all, so the gate cannot silently pass on a run that never
produced the metric.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.obs.memledger import LEDGER_TRACKS
from repro.obs.validate import validate_chrome_trace


def _load_json(path: Optional[str]):
    if not path or not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _load_jsonl(path: Optional[str]) -> Optional[List[dict]]:
    if not path or not os.path.exists(path):
        return None
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(json.loads(line))
    return out


def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def _fmt(v, nd=4) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


# --------------------------------------------------------------- sections
def build_report(trace: Optional[dict], snapshots: Optional[List[dict]],
                 audit: Optional[List[dict]], top: int = 8) -> dict:
    """Assemble the structured post-mortem; every section degrades to
    ``None`` when its input artifact is missing."""
    rep: dict = {"sections": []}

    if trace is not None:
        summary = validate_chrome_trace(trace)
        rep["trace"] = {
            "meta": trace.get("otherData", {}),
            "n_spans": summary["n_spans"],
            "span_lanes": summary["span_lanes"],
            "counters": summary["counters"],
            "ledger_tracks_present": [t for t in LEDGER_TRACKS
                                      if summary["counters"].get(t)],
        }
    else:
        rep["trace"] = None

    last = snapshots[-1] if snapshots else None
    if last is not None:
        gauges = last.get("gauges", {})
        series = last.get("series", {})
        providers = last.get("providers", {})
        mem = providers.get("memory")
        err_pts = series.get("memory.peak_error", [])
        peak_pts = series.get("memory.realized_peak", [])
        rep["memory"] = {
            "scoreboard": (mem or {}).get("scoreboard"),
            "last": (mem or {}).get("last"),
            "leak_suspects": (mem or {}).get("leak_suspects"),
            "iterations": (mem or {}).get("iterations"),
            "peak_trajectory": [p[1] for p in peak_pts[-top:]],
            "error_trajectory": [p[1] for p in err_pts[-top:]],
            "max_abs_peak_error": (max(abs(p[1]) for p in err_pts)
                                   if err_pts else None),
            "headroom_frac": gauges.get("memory.headroom_frac"),
        }
        rep["overlap"] = {
            "last": gauges.get("overlap_efficiency"),
            "points": [p[1] for p in
                       series.get("overlap_efficiency", [])[-top:]],
        }
        rep["counters_snapshot"] = last.get("counters", {})
        rep["n_snapshots"] = len(snapshots)
    else:
        rep["memory"] = rep["overlap"] = rep["counters_snapshot"] = None
        rep["n_snapshots"] = 0

    if audit is not None:
        kinds: dict = {}
        for ev in audit:
            kinds[ev.get("kind", "?")] = kinds.get(ev.get("kind", "?"), 0) + 1
        fam = lambda prefix: {k: v for k, v in sorted(kinds.items())
                              if k.startswith(prefix)}
        rep["audit"] = {
            "n_events": len(audit),
            "drift": fam("drift."),
            "policy": fam("policy."),
            "memory": fam("memory."),
            "faults": fam("fault."),
            "ladder": fam("ladder."),
            "health": fam("health."),
            "ckpt": fam("ckpt."),
            "ladder_events": [ev for ev in audit
                              if ev.get("kind", "").startswith("ladder.")
                              ][-top:],
            "leak_events": [ev for ev in audit
                            if ev.get("kind") == "memory.leak_suspect"
                            ][-top:],
            "pressure_events": [ev for ev in audit
                                if ev.get("kind") == "memory.pressure"
                                ][-top:],
        }
    else:
        rep["audit"] = None
    return rep


def render_markdown(rep: dict) -> str:
    L: List[str] = ["# Run post-mortem", ""]
    tr = rep["trace"]
    if tr is not None:
        L += ["## Trace", ""]
        if tr["meta"]:
            L.append("meta: " + ", ".join(f"{k}={v}" for k, v in
                                          sorted(tr["meta"].items())))
        L.append(f"- {tr['n_spans']} spans over lanes "
                 + ", ".join(f"{k}:{v}" for k, v in
                             sorted(tr["span_lanes"].items())))
        L.append("- counter tracks: "
                 + ", ".join(f"{k}({v})" for k, v in
                             sorted(tr["counters"].items())))
        missing = [t for t in LEDGER_TRACKS
                   if t not in tr["ledger_tracks_present"]]
        L.append("- ledger occupancy tracks: "
                 + (", ".join(tr["ledger_tracks_present"]) or "none")
                 + (f"  (missing: {', '.join(missing)})" if missing else ""))
        L.append("")
    mem = rep["memory"]
    if mem is not None:
        L += ["## Memory — predicted vs realized", ""]
        sb = mem["scoreboard"] or {}
        L.append(f"- scored iterations: {_fmt(sb.get('n'))} "
                 f"(of {_fmt(mem.get('iterations'))} closed)")
        L.append(f"- peak error: mean |e| = {_fmt(sb.get('mean_abs_error'))},"
                 f" max |e| = {_fmt(sb.get('max_abs_error'))}"
                 f" (worst step {_fmt(sb.get('worst_step'))})")
        last = mem["last"] or {}
        L.append(f"- last iteration: realized "
                 f"{_fmt_bytes(last.get('realized_peak'))}, projected "
                 f"{_fmt_bytes(last.get('projected_peak'))}, headroom "
                 f"{_fmt(last.get('headroom_frac'))}")
        L.append(f"- leak suspects: {_fmt(mem['leak_suspects'])}")
        if mem["peak_trajectory"]:
            L.append("- realized-peak trajectory (last points): "
                     + ", ".join(_fmt_bytes(v)
                                 for v in mem["peak_trajectory"]))
        if mem["error_trajectory"]:
            L.append("- peak-error trajectory: "
                     + ", ".join(_fmt(v) for v in mem["error_trajectory"]))
        L.append("")
    ov = rep["overlap"]
    if ov is not None:
        L += ["## Overlap efficiency", "",
              f"- last: {_fmt(ov['last'])}"
              + (", points: " + ", ".join(_fmt(v, 3) for v in ov["points"])
                 if ov["points"] else ""),
              ""]
    au = rep["audit"]
    if au is not None:
        L += ["## Audit events", "", f"- total: {au['n_events']}"]
        for fam in ("drift", "policy", "memory", "faults", "ladder",
                    "health", "ckpt"):
            if au[fam]:
                L.append(f"- {fam}: "
                         + ", ".join(f"{k}={v}" for k, v in
                                     au[fam].items()))
        for name, evs in (("ladder", au["ladder_events"]),
                          ("pressure", au["pressure_events"]),
                          ("leak", au["leak_events"])):
            if evs:
                L.append(f"- last {name} events:")
                for ev in evs:
                    fields = {k: v for k, v in ev.items()
                              if k not in ("seq", "t", "kind")}
                    L.append(f"    - `{ev['kind']}` "
                             + ", ".join(f"{k}={v}" for k, v in
                                         fields.items()))
        L.append("")
    return "\n".join(L) + "\n"


# ------------------------------------------------------------------- gate
def check_peak_error(rep: dict, limit: float) -> Optional[str]:
    """Return an error string when the gate fails, else ``None``."""
    mem = rep.get("memory")
    if mem is None:
        return "peak-error gate: no metrics snapshots to score"
    worst = mem.get("max_abs_peak_error")
    if worst is None:
        return ("peak-error gate: no memory.peak_error points — "
                "no iteration was scored against a projected peak")
    if worst > limit:
        return (f"peak-error gate: max |realized-projected|/projected = "
                f"{worst:.4f} exceeds limit {limit:.4f}")
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None, help="*.trace.json path")
    ap.add_argument("--metrics", default=None, help="metrics JSONL path")
    ap.add_argument("--audit", default=None, help="audit-event JSONL path")
    ap.add_argument("--out", default=None,
                    help="write the markdown post-mortem here "
                         "(default: stdout)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also dump the structured report as JSON")
    ap.add_argument("--top", type=int, default=8,
                    help="trajectory/event tail length per section")
    ap.add_argument("--check-peak-error", type=float, default=None,
                    metavar="FRAC",
                    help="exit 2 unless every scored iteration's "
                         "|peak error| <= FRAC")
    args = ap.parse_args(argv)
    if not (args.trace or args.metrics or args.audit):
        ap.error("need at least one of --trace / --metrics / --audit")
    rep = build_report(_load_json(args.trace), _load_jsonl(args.metrics),
                       _load_jsonl(args.audit), top=args.top)
    md = render_markdown(rep)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"wrote {args.out}")
    else:
        print(md, end="")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rep, f, indent=1, default=str)
        print(f"wrote {args.json_out}")
    if args.check_peak_error is not None:
        err = check_peak_error(rep, args.check_peak_error)
        if err is not None:
            print(err, file=sys.stderr)
            return 2
        print(f"peak-error gate: OK (limit {args.check_peak_error})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
