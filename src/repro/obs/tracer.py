"""Ring-buffered, always-on span tracer (repro.obs).

The paper's overlap claim ("no additional end-to-end overhead when
effectively overlapped", §7) and its profiler claim ("cheap enough to
leave on", Table 1) are both *timeline* statements — they can only be
checked by looking at when transfers ran relative to compute.  This
tracer records that timeline at a cost low enough to stay enabled in
production, in the same spirit as the monitoring hot path (ISSUE 5):

  * **bounded memory** — all numeric span state lives in preallocated
    numpy ring buffers sized at construction; recording span number
    ``capacity + k`` overwrites slot ``k``.  Nothing grows per op.
  * **bounded interning** — span *names* are interned into a dict capped
    at ``max_names``; overflow names collapse into ``"<other>"`` so a
    pathological caller cannot grow the tracer through dynamic names.
    Dynamic detail (tags, byte counts) goes into the per-slot ``arg``
    payload, which lives in a fixed-length list (ring-overwritten too).
  * **monotonic clock** — ``time.perf_counter`` throughout; export
    normalizes to the earliest retained timestamp.

Lanes are fixed: one per traffic class of the transfer engine plus
``compute`` (step execution) and ``adapt`` (the profile→drift→adapt→
apply machinery).  Fixed lanes keep the record a single uint8 and give
the Chrome-trace export a stable thread layout.

Export is Chrome trace-event JSON (``ph: "X"`` complete events plus
``ph: "C"`` counters), openable in Perfetto or ``chrome://tracing`` —
see :func:`export_chrome_trace`.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# Fixed lane set: engine traffic classes + compute + adaptation machinery.
LANE_COMPUTE = "compute"
LANE_POLICY_SWAP = "policy_swap"
LANE_KV_SPILL = "kv_spill"
LANE_CHECKPOINT = "checkpoint"
LANE_ADAPT = "adapt"
LANES: Tuple[str, ...] = (LANE_COMPUTE, LANE_POLICY_SWAP, LANE_KV_SPILL,
                          LANE_CHECKPOINT, LANE_ADAPT)
LANE_ID: Dict[str, int] = {name: i for i, name in enumerate(LANES)}

# transfer lanes considered "hideable under compute" by the overlap metric
TRANSFER_LANES: Tuple[str, ...] = (LANE_POLICY_SWAP, LANE_KV_SPILL,
                                   LANE_CHECKPOINT)

_KIND_SPAN = 0
_KIND_INSTANT = 1

_OTHER_NAME = "<other>"


class SpanTracer:
    """Fixed-capacity span recorder.  Thread-safe: the engine records from
    both the training thread and the checkpoint writer thread."""

    def __init__(self, capacity: int = 1 << 15, max_names: int = 1024):
        assert capacity >= 16
        self.capacity = int(capacity)
        self.max_names = int(max_names)
        self._lane = np.zeros(self.capacity, np.uint8)
        self._kind = np.zeros(self.capacity, np.uint8)
        self._name = np.zeros(self.capacity, np.int32)
        self._t0 = np.zeros(self.capacity, np.float64)
        self._t1 = np.zeros(self.capacity, np.float64)
        self._iter = np.full(self.capacity, -1, np.int64)
        self._arg: List[Any] = [None] * self.capacity
        self._names: Dict[str, int] = {}
        self._name_list: List[str] = []
        self._n = 0                      # total records ever (monotonic)
        self._lock = threading.Lock()
        self.current_iter = -1           # stamped onto every record
        self.enabled = True

    # ------------------------------------------------------------ interning
    def _name_id(self, name: str) -> int:
        nid = self._names.get(name)
        if nid is None:
            if len(self._name_list) >= self.max_names:
                nid = self._names.get(_OTHER_NAME)
                if nid is None:
                    nid = self._intern(_OTHER_NAME)
                return nid
            nid = self._intern(name)
        return nid

    def _intern(self, name: str) -> int:
        nid = len(self._name_list)
        self._names[name] = nid
        self._name_list.append(name)
        return nid

    # ------------------------------------------------------------ recording
    def record(self, lane: str, name: str, t0: float, t1: float,
               arg: Any = None) -> None:
        """Record one completed span.  ``t0``/``t1`` are perf_counter
        readings taken by the caller (so the record call itself is not
        inside the measured interval)."""
        if not self.enabled:
            return
        lid = LANE_ID[lane]
        with self._lock:
            i = self._n % self.capacity
            self._lane[i] = lid
            self._kind[i] = _KIND_SPAN
            self._name[i] = self._name_id(name)
            self._t0[i] = t0
            self._t1[i] = t1
            self._iter[i] = self.current_iter
            self._arg[i] = arg
            self._n += 1

    def instant(self, lane: str, name: str, t: Optional[float] = None,
                arg: Any = None) -> None:
        """Record a zero-duration marker (Chrome ``ph: "i"``)."""
        if not self.enabled:
            return
        ts = time.perf_counter() if t is None else t
        lid = LANE_ID[lane]
        with self._lock:
            i = self._n % self.capacity
            self._lane[i] = lid
            self._kind[i] = _KIND_INSTANT
            self._name[i] = self._name_id(name)
            self._t0[i] = ts
            self._t1[i] = ts
            self._iter[i] = self.current_iter
            self._arg[i] = arg
            self._n += 1

    @contextmanager
    def span(self, lane: str, name: str, arg: Any = None):
        """Context manager form; records on exit (exceptions included)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(lane, name, t0, time.perf_counter(), arg)

    def set_iteration(self, it: int) -> None:
        self.current_iter = int(it)

    # ------------------------------------------------------------- reading
    def _valid(self) -> np.ndarray:
        """Indices of retained records in recording order."""
        n = min(self._n, self.capacity)
        if self._n <= self.capacity:
            return np.arange(n)
        head = self._n % self.capacity
        return np.concatenate([np.arange(head, self.capacity),
                               np.arange(0, head)])

    def spans(self, lanes: Optional[Sequence[str]] = None,
              it: Optional[int] = None,
              kinds: Tuple[int, ...] = (_KIND_SPAN,)) -> np.ndarray:
        """Retained spans as an ``(n, 2)`` float array of (t0, t1),
        optionally filtered by lane set and iteration stamp."""
        with self._lock:
            idx = self._valid()
            mask = np.isin(self._kind[idx], list(kinds))
            if lanes is not None:
                lids = [LANE_ID[l] for l in lanes]
                mask &= np.isin(self._lane[idx], lids)
            if it is not None:
                mask &= self._iter[idx] == it
            idx = idx[mask]
            return np.stack([self._t0[idx], self._t1[idx]], axis=1)

    def records(self) -> List[dict]:
        """Retained records as dicts (export / debugging path — not hot)."""
        with self._lock:
            out = []
            for i in self._valid():
                out.append({
                    "lane": LANES[self._lane[i]],
                    "kind": ("span" if self._kind[i] == _KIND_SPAN
                             else "instant"),
                    "name": self._name_list[self._name[i]],
                    "t0": float(self._t0[i]),
                    "t1": float(self._t1[i]),
                    "iter": int(self._iter[i]),
                    "arg": self._arg[i],
                })
            return out

    # --------------------------------------------------------------- admin
    def clear(self) -> None:
        with self._lock:
            self._n = 0
            self._iter.fill(-1)
            self._arg = [None] * self.capacity
            self._names.clear()
            self._name_list.clear()
            self.current_iter = -1

    def stats(self) -> dict:
        with self._lock:
            return {
                "n_spans": self._n,
                "retained": min(self._n, self.capacity),
                "dropped": max(self._n - self.capacity, 0),
                "capacity": self.capacity,
                "names": len(self._name_list),
            }


# ------------------------------------------------------------------ export
def _json_safe(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (tuple, list)):
        return [_json_safe(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return repr(v)


def chrome_trace_events(tracer: SpanTracer,
                        counters: Optional[Dict[str, Iterable[Tuple[float, float]]]] = None
                        ) -> List[dict]:
    """Chrome trace-event list: thread-name metadata per lane, ``X``
    complete events for spans, ``i`` instants, and ``C`` counter tracks
    (e.g. per-iteration overlap efficiency)."""
    recs = tracer.records()
    t_min = min([r["t0"] for r in recs]
                + [t for vs in (counters or {}).values() for t, _ in vs],
                default=0.0)
    ev: List[dict] = []
    for i, lane in enumerate(LANES):
        ev.append({"name": "thread_name", "ph": "M", "pid": 0, "tid": i,
                   "args": {"name": lane}})
        ev.append({"name": "thread_sort_index", "ph": "M", "pid": 0,
                   "tid": i, "args": {"sort_index": i}})
    for r in recs:
        tid = LANE_ID[r["lane"]]
        ts = (r["t0"] - t_min) * 1e6
        args = {"iter": r["iter"]}
        if r["arg"] is not None:
            args["detail"] = _json_safe(r["arg"])
        if r["kind"] == "span":
            ev.append({"name": r["name"], "cat": r["lane"], "ph": "X",
                       "ts": ts, "dur": max((r["t1"] - r["t0"]) * 1e6, 0.0),
                       "pid": 0, "tid": tid, "args": args})
        else:
            ev.append({"name": r["name"], "cat": r["lane"], "ph": "i",
                       "ts": ts, "s": "t", "pid": 0, "tid": tid,
                       "args": args})
    for cname, values in (counters or {}).items():
        for t, v in values:
            ev.append({"name": cname, "ph": "C", "pid": 0,
                       "ts": (t - t_min) * 1e6,
                       "args": {"value": _json_safe(v)}})
    return ev


def export_chrome_trace(path: str, tracer: SpanTracer,
                        counters: Optional[Dict[str, Iterable[Tuple[float, float]]]] = None,
                        meta: Optional[dict] = None) -> str:
    """Write ``path`` as a Chrome trace-event JSON object (the dict form,
    so ``otherData`` can carry run metadata).  Open it in Perfetto
    (https://ui.perfetto.dev) or ``chrome://tracing``."""
    obj = {
        "traceEvents": chrome_trace_events(tracer, counters),
        "displayTimeUnit": "ms",
        "otherData": _json_safe({"tracer": tracer.stats(),
                                 **(meta or {})}),
    }
    with open(path, "w") as f:
        json.dump(obj, f)
    return path
