"""Per-iteration realized HBM occupancy ledger (repro.obs.memledger).

``Simulator``/``projected_peak`` predict what the peak *should* be;
nothing so far reconstructed what it *was*.  The ledger closes that
loop: subsystems feed it the observed events of each iteration — the
executed policy's mirrored swap copies and their ``advance_op`` release
points, engine copy/release outcomes per traffic class, KV-spill and
checkpoint staging, pool slab counters — and at the iteration boundary
it replays them into a per-op realized-occupancy timeline, mirroring
``core/memtrace.build_timeline`` but from observations instead of
profiled predictions.

Derived per iteration:

  * **realized peak** + top-k tensor/layer attribution at the peak op;
  * **predicted-vs-realized peak error** — the Simulator accuracy
    scoreboard (``memory.peak_error`` gauge, ``memory.peak`` audit
    events).  On a clean run every observed swap-out retires at its
    promised release op, so realized == projected exactly; the error is
    precisely the execution's divergence from the plan (failed
    swap-outs retained in HBM, late releases);
  * **budget headroom** (``memory.headroom_frac``) — consumed by the
    runtime's health FSM so the degradation ladder reacts to shrinking
    margin *before* an OOM;
  * **byte conservation** — allocated == resident + freed across
    pool/engine/kvspill per iteration, with leak suspects named
    (terminal transfer failures, pool imbalance).

Occupancy is also kept as bounded counter-track series
(:data:`LEDGER_TRACKS`: ``hbm_dynamic``, ``swapped_out``, ``host_pool``,
``kv_spill``) for Perfetto export alongside the span lanes.

Layering: this module sits at the bottom of the stack with the other
``repro.obs`` pillars — it never imports ``repro.core`` or
``repro.hostmem``; profiles, swap policies and pool stats arrive as
duck-typed arguments, and traffic classes are matched by name.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

# Perfetto counter tracks exported next to the span lanes.
LEDGER_TRACKS: Tuple[str, ...] = ("hbm_dynamic", "swapped_out",
                                  "host_pool", "kv_spill")

# engine traffic-class names (matched by string — obs is below hostmem)
_CLS_POLICY = "policy_swap"
_CLS_KV = "kv_spill"
_CLS_CKPT = "checkpoint"
_CLS_TRACK = {_CLS_POLICY: "swapped_out", _CLS_KV: "kv_spill"}

#: keys every per-iteration ledger record carries (schema-pinned)
RECORD_KEYS = ("step", "t", "realized_peak", "realized_dynamic_peak",
               "peak_op", "projected_peak", "peak_error", "headroom_frac",
               "budget", "attribution", "n_swap_entries", "n_observed",
               "n_failed", "n_unobserved", "conservation")
CONSERVATION_KEYS = ("ok", "allocated", "freed", "resident_delta",
                     "suspects")


def _entry_tag(e) -> str:
    """Identical to ``SwapPolicy.entry_tag`` (duplicated — no core import)."""
    return f"{getattr(e, 'site', None) or 'tensor'}:{e.layer}:{e.uid}"


def _clamp(v: int, lo: int, hi: int) -> int:
    return min(max(v, lo), hi)


class MemoryLedger:
    """Observed-event HBM accounting.  All state is bounded (ring buffers
    per track / per iteration record), so the ledger stays always-on like
    the tracer and the audit log."""

    def __init__(self, max_iterations: int = 512,
                 track_points: int = 4096, max_window_events: int = 8192,
                 top_k: int = 5):
        self.top_k = int(top_k)
        self._lock = threading.Lock()
        self._tracks: Dict[str, Deque[Tuple[float, float]]] = {
            name: collections.deque(maxlen=track_points)
            for name in LEDGER_TRACKS}
        # host-resident staged bytes per engine traffic class (running)
        self._staged: Dict[str, int] = {}
        # swap-out outcomes observed this window: tag -> {failed, release_op}
        self._observed: Dict[str, dict] = {}
        self._window_failed: List[dict] = []
        self._max_window_events = int(max_window_events)
        self.iterations: Deque[dict] = collections.deque(
            maxlen=max_iterations)
        # replay cache: the base (no-swap) delta array + uid index per
        # profile, rebuilt only when the profile object changes
        self._cache_key: Optional[tuple] = None
        self._cache: Optional[tuple] = None
        self._prev_pool: Optional[dict] = None
        # ---- counters ----
        self.n_events = 0
        self.n_events_dropped = 0        # window overflow (cap, never grows)
        self.n_leak_suspects = 0
        self.n_iterations = 0

    # ------------------------------------------------------- event feed
    def note_transfer(self, kind: str, cls: str, tag: str, nbytes: int, *,
                      failed: bool = False, release_op: int = -1,
                      t: Optional[float] = None) -> None:
        """An engine copy retired (``kind`` = ``"out"``/``"in"``).  Failed
        transfers become leak suspects for this window; successful ones
        move the per-class staged-byte gauges and, for policy-swap
        D2H copies, record the observed outcome the replay consumes."""
        nbytes = int(nbytes)
        with self._lock:
            self.n_events += 1
            if failed:
                if len(self._window_failed) < self._max_window_events:
                    self._window_failed.append({
                        "tag": tag[:64], "cls": cls, "dir": kind,
                        "nbytes": nbytes,
                        "reason": f"swap_{kind}_failed"})
                else:
                    self.n_events_dropped += 1
                if kind == "out" and cls == _CLS_POLICY:
                    self._note_observed(tag, failed=True,
                                        release_op=release_op)
                return
            if kind == "out":
                self._staged[cls] = self._staged.get(cls, 0) + nbytes
                if cls == _CLS_POLICY:
                    self._note_observed(tag, failed=False,
                                        release_op=release_op)
            else:
                self._staged[cls] = max(
                    self._staged.get(cls, 0) - nbytes, 0)
            self._point(cls, t)

    def note_release(self, cls: str, tag: str, nbytes: int,
                     t: Optional[float] = None) -> None:
        """Staged host bytes returned to the pool *without* an H2D copy
        (KV-spill discard, checkpoint writer collecting its slabs)."""
        with self._lock:
            self.n_events += 1
            self._staged[cls] = max(
                self._staged.get(cls, 0) - int(nbytes), 0)
            self._point(cls, t)

    def _note_observed(self, tag: str, *, failed: bool,
                       release_op: int) -> None:
        if len(self._observed) < self._max_window_events:
            self._observed[tag] = {"failed": failed,
                                   "release_op": int(release_op)}
        else:
            self.n_events_dropped += 1

    def _point(self, cls: str, t: Optional[float]) -> None:
        track = _CLS_TRACK.get(cls)
        if track is not None:
            self._tracks[track].append(
                (time.perf_counter() if t is None else t,
                 float(self._staged.get(cls, 0))))

    # -------------------------------------------------- iteration close
    def close_iteration(self, step: int, *, profile=None, swap=None,
                        budget: Optional[int] = None,
                        pool_stats: Optional[dict] = None,
                        t: Optional[float] = None) -> dict:
        """Close the iteration window: replay the observed events into a
        realized-occupancy timeline, score it against the executed
        policy's ``projected_peak``, run the byte-conservation check, and
        append the four counter-track points.  Returns the iteration
        record (also kept in the bounded ``iterations`` ring)."""
        t = time.perf_counter() if t is None else t
        with self._lock:
            observed, self._observed = self._observed, {}
            failed, self._window_failed = self._window_failed, []
            staged_policy = self._staged.get(_CLS_POLICY, 0)
            staged_kv = self._staged.get(_CLS_KV, 0)
        realized = self._realize(profile, swap, observed)
        (dyn_peak, peak_op, static, attribution,
         n_obs, n_fail, n_unobs) = realized
        realized_peak = dyn_peak + static
        projected = None
        error = None
        headroom = None
        if swap is not None and profile is not None:
            projected = int(getattr(swap, "projected_peak", 0)) or None
            if projected:
                error = (realized_peak - projected) / projected
            if budget:
                headroom = (budget - realized_peak) / budget
        conservation = self._conserve(pool_stats, failed)
        rec = {
            "step": int(step), "t": t,
            "realized_peak": int(realized_peak),
            "realized_dynamic_peak": int(dyn_peak),
            "peak_op": int(peak_op),
            "projected_peak": projected,
            "peak_error": error,
            "headroom_frac": headroom,
            "budget": int(budget) if budget else None,
            "attribution": attribution,
            "n_swap_entries": (len(swap.entries)
                               if swap is not None else 0),
            "n_observed": n_obs, "n_failed": n_fail,
            "n_unobserved": n_unobs,
            "conservation": conservation,
        }
        host_pool = (pool_stats or {}).get("bytes_in_use", 0)
        with self._lock:
            self.n_iterations += 1
            self.iterations.append(rec)
            self._tracks["hbm_dynamic"].append((t, float(dyn_peak)))
            self._tracks["swapped_out"].append((t, float(staged_policy)))
            self._tracks["host_pool"].append((t, float(host_pool)))
            self._tracks["kv_spill"].append((t, float(staged_kv)))
            if not conservation["ok"]:
                self.n_leak_suspects += len(conservation["suspects"])
        self._publish(rec)
        return rec

    # ------------------------------------------------------- the replay
    def _base(self, profile):
        """Cached no-swap delta array + uid->tensor index for a profile."""
        key = (id(profile), profile.n_ops, len(profile.tensors))
        if self._cache_key != key:
            n = int(profile.n_ops)
            delta = np.zeros(n + 2, np.int64)
            by_uid = {}
            for tt in profile.tensors:
                b = _clamp(tt.birth, 0, n)
                d = _clamp(tt.death, b, n + 1)
                delta[b] += tt.nbytes
                delta[d] -= tt.nbytes
                by_uid[tt.uid] = tt
            self._cache_key, self._cache = key, (delta, by_uid)
        return self._cache

    def _realize(self, profile, swap, observed: Dict[str, dict]):
        """Per-op realized occupancy: the profiled tensor liveness with
        off-device windows applied only for swap entries whose D2H was
        *observed* to complete (at the observed release op) — a failed
        swap-out was retained in HBM and contributes no reduction;
        entries the mirror cap kept unobserved fall back to their
        planned windows."""
        if profile is None:
            return 0, 0, 0, [], 0, 0, 0
        n = int(profile.n_ops)
        base, by_uid = self._base(profile)
        delta = base.copy()
        off: Dict[Any, Tuple[int, int]] = {}
        n_obs = n_fail = n_unobs = 0
        for e in (swap.entries if swap is not None else ()):
            tt = by_uid.get(e.uid)
            if tt is None:
                continue
            ob = observed.get(_entry_tag(e))
            if ob is None:
                out_op, back = e.swap_out_done_op, e.swap_in_op
                n_unobs += 1
            elif ob["failed"]:
                n_fail += 1
                continue                     # retained in HBM
            else:
                out_op = (ob["release_op"] if ob["release_op"] >= 0
                          else e.swap_out_done_op)
                back = e.swap_in_op
                n_obs += 1
            b = _clamp(tt.birth, 0, n)
            d = _clamp(tt.death, b, n + 1)
            out_op = _clamp(out_op, b, d)
            back = _clamp(back, out_op, d)
            if back > out_op:
                delta[out_op] -= tt.nbytes
                delta[back] += tt.nbytes
                off[e.uid] = (out_op, back)
        usage = np.cumsum(delta)[: n + 1]
        peak_op = int(np.argmax(usage)) if usage.size else 0
        dyn_peak = int(usage[peak_op]) if usage.size else 0
        resident = []
        for tt in profile.tensors:
            b = _clamp(tt.birth, 0, n)
            d = _clamp(tt.death, b, n + 1)
            if not b <= peak_op < d:
                continue
            w = off.get(tt.uid)
            if w is not None and w[0] <= peak_op < w[1]:
                continue                     # off-device at the peak
            resident.append(tt)
        resident.sort(key=lambda tt: -tt.nbytes)
        attribution = [{"tag": _entry_tag(tt), "nbytes": int(tt.nbytes),
                        "layer": int(getattr(tt, "layer", -1)),
                        "site": getattr(tt, "site", None)}
                       for tt in resident[: self.top_k]]
        return (dyn_peak, peak_op, int(profile.static_bytes), attribution,
                n_obs, n_fail, n_unobs)

    # -------------------------------------------------- byte conservation
    def _conserve(self, pool_stats: Optional[dict],
                  failed: List[dict]) -> dict:
        """allocated == resident + freed, per iteration: the pool's
        cumulative alloc/free byte counters must exactly explain the
        resident-byte delta since the last close; any terminal transfer
        failure this window is a named leak suspect."""
        suspects = list(failed)
        allocated = freed = resident_delta = 0
        if pool_stats is not None:
            prev = self._prev_pool or {}
            allocated = (pool_stats.get("bytes_alloc_total", 0)
                         - prev.get("bytes_alloc_total", 0))
            freed = (pool_stats.get("bytes_freed_total", 0)
                     - prev.get("bytes_freed_total", 0))
            resident_delta = (pool_stats.get("bytes_in_use", 0)
                              - prev.get("bytes_in_use", 0))
            if allocated - freed != resident_delta:
                suspects.append({
                    "tag": "pool", "cls": "pool", "dir": "-",
                    "nbytes": allocated - freed - resident_delta,
                    "reason": "pool_imbalance"})
            self._prev_pool = {
                k: pool_stats.get(k, 0)
                for k in ("bytes_alloc_total", "bytes_freed_total",
                          "bytes_in_use")}
        return {"ok": not suspects, "allocated": int(allocated),
                "freed": int(freed), "resident_delta": int(resident_delta),
                "suspects": suspects}

    # ------------------------------------------------------- publication
    def _publish(self, rec: dict) -> None:
        """memory.* gauges + audit events (late obs import: this module
        is itself part of the repro.obs package)."""
        from repro import obs
        m = obs.metrics()
        m.gauge("memory.realized_peak", rec["realized_peak"], t=rec["t"])
        if rec["projected_peak"] is not None:
            m.gauge("memory.projected_peak", rec["projected_peak"],
                    t=rec["t"])
        if rec["peak_error"] is not None:
            m.gauge("memory.peak_error", rec["peak_error"], t=rec["t"])
        if rec["headroom_frac"] is not None:
            m.gauge("memory.headroom_frac", rec["headroom_frac"],
                    t=rec["t"])
        cons = rec["conservation"]
        obs.audit().event(
            "memory.peak", step=rec["step"],
            realized=rec["realized_peak"], projected=rec["projected_peak"],
            error=(round(rec["peak_error"], 4)
                   if rec["peak_error"] is not None else None),
            peak_op=rec["peak_op"], n_failed=rec["n_failed"])
        if not cons["ok"]:
            m.counter("memory.leak_suspects", len(cons["suspects"]))
            obs.audit().event(
                "memory.leak_suspect", step=rec["step"],
                n=len(cons["suspects"]),
                suspects=[s["tag"] for s in cons["suspects"][:8]],
                reasons=sorted({s["reason"] for s in cons["suspects"]}))

    # ------------------------------------------------------------ queries
    def counter_tracks(self) -> Dict[str, List[Tuple[float, float]]]:
        """The four occupancy tracks in ``chrome_trace_events``'
        ``counters=`` shape (name -> [(t, value), ...])."""
        with self._lock:
            return {name: list(pts) for name, pts in self._tracks.items()}

    def scoreboard(self) -> dict:
        """Simulator accuracy over the retained iterations: how far the
        realized peak landed from ``projected_peak``."""
        with self._lock:
            scored = [r for r in self.iterations
                      if r["peak_error"] is not None]
        errs = [abs(r["peak_error"]) for r in scored]
        worst = max(scored, key=lambda r: abs(r["peak_error"]),
                    default=None)
        return {
            "n": len(scored),
            "mean_abs_error": float(np.mean(errs)) if errs else None,
            "max_abs_error": float(max(errs)) if errs else None,
            "worst_step": worst["step"] if worst else None,
            "last_error": scored[-1]["peak_error"] if scored else None,
        }

    def staged_bytes(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._staged)

    def last(self) -> Optional[dict]:
        with self._lock:
            return self.iterations[-1] if self.iterations else None

    def stats(self) -> dict:
        last = self.last()
        return {
            "iterations": self.n_iterations,
            "events": self.n_events,
            "events_dropped": self.n_events_dropped,
            "leak_suspects": self.n_leak_suspects,
            "staged_bytes": self.staged_bytes(),
            "scoreboard": self.scoreboard(),
            "last": ({k: last[k] for k in
                      ("step", "realized_peak", "projected_peak",
                       "peak_error", "headroom_frac", "n_failed")}
                     if last else None),
        }

    def clear(self) -> None:
        with self._lock:
            for pts in self._tracks.values():
                pts.clear()
            self._staged.clear()
            self._observed.clear()
            self._window_failed.clear()
            self.iterations.clear()
            self._cache_key = self._cache = None
            self._prev_pool = None
            self.n_events = self.n_events_dropped = 0
            self.n_leak_suspects = self.n_iterations = 0
