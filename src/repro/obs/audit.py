"""Drift audit log (repro.obs).

The profile → drift-detect → tier-decide → apply loop used to leave no
record: after a run you could see *that* the policystore reported
``reuse=3 warm=1`` but not which fingerprint matched which record at
what similarity, which guard demoted a decision, or which policy was
actually applied at which step.  The audit log makes each decision a
structured event:

    {"seq": 17, "t": ..., "kind": "drift.classify",
     "tier": "reuse", "similarity": 0.993, "fp": "a3f9...",
     "record": "b21c...", "reason": "sim=0.993"}

Event kinds emitted by the wired subsystems:

  * ``stage.transition``   — StageMachine moves (WarmUp/GenPolicy/Stable)
  * ``drift.classify``     — DriftClassifier tier decision + guards
  * ``drift.demote``       — apply-time demotion (match-miss etc.)
  * ``policy.apply``       — a policy became the runtime's applied policy
  * ``policy.store_put``   — adaptation winner written back to the store
  * ``adaptation.done``    — one adaptation episode closed (tier, steps,
    seconds, GenPolicy step count)

Storage is a bounded deque (``capacity`` events, oldest dropped) plus an
optional append-only JSONL file for post-mortem inspection — attach with
``attach_file(path)``.  Like the tracer, memory never grows per event.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Deque, List, Optional

import collections

from repro.obs.tracer import _json_safe


class AuditLog:
    def __init__(self, capacity: int = 4096, path: Optional[str] = None):
        self.capacity = int(capacity)
        self._events: Deque[dict] = collections.deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self._path: Optional[str] = None
        self._file = None
        if path:
            self.attach_file(path)

    # ------------------------------------------------------------- writing
    def event(self, kind: str, /, **fields) -> dict:
        # reserved keys stay authoritative: a payload field named "kind"
        # must not silently rename the event
        ev = dict(_json_safe(fields))
        ev.update({"seq": None, "t": time.time(), "kind": kind})
        with self._lock:
            self._seq += 1
            ev["seq"] = self._seq
            self._events.append(ev)
            if self._file is not None:
                try:
                    self._file.write(json.dumps(ev) + "\n")
                    self._file.flush()
                except OSError:
                    self._file = None      # keep the in-memory log alive
        return ev

    def attach_file(self, path: str) -> None:
        with self._lock:
            if self._file is not None:
                try:
                    self._file.close()
                except OSError:
                    pass
            self._path = path
            self._file = open(path, "a")

    def detach_file(self) -> None:
        with self._lock:
            if self._file is not None:
                try:
                    self._file.close()
                except OSError:
                    pass
            self._file = None
            self._path = None

    # ------------------------------------------------------------- reading
    def tail(self, n: int = 50, kind: Optional[str] = None) -> List[dict]:
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        return evs[-n:]

    def counts(self) -> dict:
        with self._lock:
            out: dict = {}
            for e in self._events:
                out[e["kind"]] = out.get(e["kind"], 0) + 1
            return out

    def stats(self) -> dict:
        with self._lock:
            return {"n_events": self._seq,
                    "retained": len(self._events),
                    "capacity": self.capacity,
                    "file": self._path}

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._seq = 0
