"""Chrome trace-event / metrics-JSONL schema validation (repro.obs).

Used by the nightly workflow to prove an exported ``*.trace.json``
actually loads as a Chrome trace (Perfetto / ``chrome://tracing``),
covers the expected lanes, and carries the overlap-efficiency counter
before the artifact is uploaded:

    PYTHONPATH=src python -m repro.obs.validate out.trace.json \
        --require-lanes compute,policy_swap,kv_spill,checkpoint,adapt \
        --require-counters overlap_efficiency,hbm_dynamic,swapped_out \
        --require-providers memory \
        --metrics metrics.jsonl

Also importable (``validate_chrome_trace``) so tests assert the same
schema the workflow enforces.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterable, Optional

from repro.obs.metrics import SNAPSHOT_KEYS
from repro.obs.tracer import LANES

_REQUIRED_EVENT_KEYS = {"name", "ph", "pid"}
_PHASES_WITH_TS = {"X", "i", "C"}


def validate_chrome_trace(obj: dict, *,
                          require_lanes: Iterable[str] = (),
                          require_counter: Optional[str] = None,
                          require_counters: Iterable[str] = ()) -> dict:
    """Validate a loaded trace object; returns a summary dict.  Raises
    ``ValueError`` with a precise message on the first schema problem."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a JSON object with 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")
    lanes_named: Dict[int, str] = {}
    span_lanes: Dict[str, int] = {}
    counters: Dict[str, int] = {}
    n_spans = n_instants = 0
    for k, e in enumerate(events):
        if not isinstance(e, dict) or not _REQUIRED_EVENT_KEYS <= set(e):
            raise ValueError(f"event {k} missing required keys "
                             f"{sorted(_REQUIRED_EVENT_KEYS - set(e))}")
        ph = e["ph"]
        if ph in _PHASES_WITH_TS and not isinstance(e.get("ts"), (int, float)):
            raise ValueError(f"event {k} (ph={ph!r}) has no numeric 'ts'")
        if ph == "M" and e["name"] == "thread_name":
            lanes_named[e.get("tid", -1)] = e["args"]["name"]
        elif ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {k} ('{e['name']}') has bad dur "
                                 f"{dur!r}")
            lane = e.get("cat", lanes_named.get(e.get("tid"), "?"))
            span_lanes[lane] = span_lanes.get(lane, 0) + 1
            n_spans += 1
        elif ph == "i":
            n_instants += 1
        elif ph == "C":
            if "value" not in e.get("args", {}):
                raise ValueError(f"counter event {k} ('{e['name']}') has no "
                                 "args.value")
            counters[e["name"]] = counters.get(e["name"], 0) + 1
    missing_meta = [l for l in LANES if l not in lanes_named.values()]
    if missing_meta:
        raise ValueError(f"missing thread_name metadata for lanes "
                         f"{missing_meta}")
    for lane in require_lanes:
        if span_lanes.get(lane, 0) == 0:
            raise ValueError(f"no spans on required lane {lane!r} "
                             f"(got {span_lanes})")
    wanted = list(require_counters)
    if require_counter is not None:
        wanted.append(require_counter)
    for cname in wanted:
        if counters.get(cname, 0) == 0:
            raise ValueError(f"no '{cname}' counter events "
                             f"(got {sorted(counters)})")
    return {"n_events": len(events), "n_spans": n_spans,
            "n_instants": n_instants, "span_lanes": span_lanes,
            "counters": counters}


def validate_metrics_jsonl(path: str, *,
                           require_gauges: Iterable[str] = (),
                           require_providers: Iterable[str] = ()) -> dict:
    """Every line must be a registry snapshot with the documented keys;
    the *last* snapshot must additionally carry the required gauges and
    provider blocks (e.g. the ledger's ``memory`` provider)."""
    n = 0
    last = None
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            snap = json.loads(line)
            missing = [k for k in SNAPSHOT_KEYS if k not in snap]
            if missing:
                raise ValueError(f"snapshot line {i} missing keys {missing}")
            n += 1
            last = snap
    if n == 0:
        raise ValueError(f"{path}: no snapshots")
    for g in require_gauges:
        if g not in last.get("gauges", {}):
            raise ValueError(f"last snapshot missing gauge {g!r} "
                             f"(got {sorted(last.get('gauges', {}))})")
    for p in require_providers:
        if p not in last.get("providers", {}):
            raise ValueError(f"last snapshot missing provider {p!r} "
                             f"(got {sorted(last.get('providers', {}))})")
    return {"snapshots": n, "gauges": sorted(last.get("gauges", {})),
            "providers": sorted(last.get("providers", {}))}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="*.trace.json path")
    ap.add_argument("--require-lanes", default="",
                    help="comma-separated lanes that must carry >=1 span")
    ap.add_argument("--require-counter", default=None,
                    help="counter track that must be present (e.g. "
                         "overlap_efficiency)")
    ap.add_argument("--require-counters", default="",
                    help="comma-separated counter tracks that must all be "
                         "present (e.g. hbm_dynamic,swapped_out)")
    ap.add_argument("--require-gauges", default="",
                    help="gauges the last metrics snapshot must carry")
    ap.add_argument("--require-providers", default="",
                    help="provider blocks the last metrics snapshot must "
                         "carry (e.g. memory)")
    ap.add_argument("--metrics", default=None,
                    help="also validate this metrics JSONL file")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        obj = json.load(f)
    split = lambda s: [x for x in s.split(",") if x]
    summary = validate_chrome_trace(
        obj, require_lanes=split(args.require_lanes),
        require_counter=args.require_counter,
        require_counters=split(args.require_counters))
    print(f"{args.trace}: OK — {summary['n_spans']} spans over lanes "
          f"{summary['span_lanes']}, counters {summary['counters']}")
    if args.metrics:
        ms = validate_metrics_jsonl(
            args.metrics, require_gauges=split(args.require_gauges),
            require_providers=split(args.require_providers))
        print(f"{args.metrics}: OK — {ms['snapshots']} snapshots, "
              f"providers {ms['providers']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
