"""Fault-tolerant sharded checkpointing.

Design targets (1000+-node posture):
  * **atomicity** — writes go to ``step_N.tmp`` and are renamed only after
    the manifest (with per-array checksums) is fsynced; a crashed writer can
    never produce a ``step_N`` directory that restore would trust;
  * **async** — a background thread serializes device arrays snapshotted at
    save() call time, so the train loop loses only the host-transfer time;
  * **per-process shards** — each process writes ``arrays.p{i}.npz`` holding
    its addressable shards (on this single-process container, one file);
  * **elastic restore** — arrays are saved with their global shape; restore
    re-``device_put``s against *any* new mesh/sharding, so the job can come
    back on a different topology (elastic scaling / failed-node exclusion);
  * **emergency saves** — the trainer calls ``save(..., block=True)`` from
    its failure handler;
  * **host-memory tier integration** — with a ``repro.hostmem`` transfer
    engine attached, snapshot staging routes through the engine's
    ``checkpoint`` traffic class: the drain queues on the lowest-priority
    stream, so concurrent policy swaps and KV spills preempt it at
    transfer granularity instead of stalling behind it, and the staged
    bytes recycle through the pinned slab pool.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro import faults, obs


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # bfloat16 etc: exact widen for npz
            arr = np.asarray(leaf).astype(np.float32)
        flat[key] = arr
    return flat


class CheckpointManager:
    # shard writes get a short bounded retry before the whole save fails —
    # transient filesystem hiccups should not cost a checkpoint
    WRITE_RETRIES = 2

    def __init__(self, directory: str, keep: int = 3,
                 process_index: Optional[int] = None, engine=None,
                 on_error: str = "raise"):
        if on_error not in ("raise", "degrade"):
            raise ValueError(f"on_error must be 'raise' or 'degrade', "
                             f"got {on_error!r}")
        self.dir = directory
        self.keep = keep
        self.proc = (jax.process_index() if process_index is None
                     else process_index)
        os.makedirs(directory, exist_ok=True)
        # optional repro.hostmem TransferEngine: snapshot staging goes
        # through its lowest-priority "checkpoint" traffic class
        self.engine = engine
        # "raise": an async write failure surfaces on the next wait()
        # (legacy, fail-stop).  "degrade": it is audited and counted —
        # training continues with one fewer restore point, matching the
        # paper's training-never-crashes posture.
        self.on_error = on_error
        self.n_write_failures = 0
        self.n_restore_fallbacks = 0
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -------------------------------------------------- engine staging
    def _stage(self, name: str, flat: Dict[str, np.ndarray]):
        """Queue every array on the checkpoint-class D2H stream; the
        writer thread collects the staged bytes later (the engine lock
        makes the cross-thread drain safe).  save() widens the class
        window to the whole drain first, so nothing executes inline in
        the training thread and every copy stays preemptible."""
        from repro.hostmem.engine import TC_CHECKPOINT
        staged = {}
        for key, arr in flat.items():
            if arr.nbytes == 0:           # pool rejects empty reservations
                staged[key] = arr
                continue
            staged[key] = self.engine.submit_swap_out(
                arr, tag=f"ckpt/{name}/{key}", cls=TC_CHECKPOINT)
        return staged

    def _collect(self, staged: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Drain the staged events back to plain arrays (writer side) and
        recycle their slabs."""
        out = {}
        for key, ev in staged.items():
            if isinstance(ev, np.ndarray):
                out[key] = ev
                continue
            self.engine.wait(ev)
            if ev.failed:
                # staging failed terminally: the engine retained the
                # source in HBM (ev.result) and already freed the slab —
                # snapshot it with a plain host copy instead
                out[key] = np.asarray(ev.result)
                continue
            out[key] = ev.block.read()
            self.engine.pool.free(ev.block)
            # staged checkpoint bytes leave the host tier here, with no
            # H2D copy — balance the ledger's per-class gauge
            obs.ledger().note_release(ev.cls, ev.tag, ev.nbytes)
        return out

    # ---------------------------------------------------------------- save
    def save(self, step: int, trees: Dict[str, Any],
             extra: Optional[dict] = None, block: bool = False) -> str:
        """Snapshot now, write async (unless block=True)."""
        self.wait()
        with obs.tracer().span(obs.LANE_CHECKPOINT, "ckpt.snapshot",
                               arg=step):
            snap = {name: _flatten(tree) for name, tree in trees.items()
                    if tree is not None}
        if self.engine is not None:
            from repro.hostmem.engine import TC_CHECKPOINT
            # widen the class window to the whole drain so no copy is
            # forced inline here — the writer thread drains them all
            self.engine.set_class_depth(
                TC_CHECKPOINT,
                sum(len(flat) for flat in snap.values()) + 2)
            with obs.tracer().span(obs.LANE_CHECKPOINT, "ckpt.stage",
                                   arg=step):
                snap = {name: self._stage(name, flat)
                        for name, flat in snap.items()}
        extra = dict(extra or {})
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp.{self.proc}"

        def write():
            try:
                with obs.tracer().span(obs.LANE_CHECKPOINT, "ckpt.write",
                                       arg=step):
                    self._write_body(step, snap, extra, tmp, final)
            except BaseException as e:   # surfaced on next wait()
                self._error = e
                if self.engine is not None:   # recycle any staged slabs
                    try:
                        for flat in snap.values():
                            for ev in flat.values():
                                if isinstance(ev, np.ndarray):
                                    continue
                                self.engine.wait(ev)
                                if ev.block is not None and not ev.block.freed:
                                    self.engine.pool.free(ev.block)
                                    obs.ledger().note_release(
                                        ev.cls, ev.tag, ev.nbytes)
                    except BaseException:
                        pass

        if block:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return final

    def _write_body(self, step, snap, extra, tmp, final):
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(),
                    "process_count": jax.process_count(),
                    "extra": extra, "trees": {}}
        for name, flat in snap.items():
            if self.engine is not None:
                with obs.tracer().span(obs.LANE_CHECKPOINT, "ckpt.collect",
                                       arg=name):
                    flat = self._collect(flat)
            fname = f"{name}.p{self.proc}.npz"
            path = os.path.join(tmp, fname)
            self._write_shard(path, fname, flat)
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["trees"][name] = {
                "file": fname, "sha256": digest,
                "keys": sorted(flat.keys())}
        mpath = os.path.join(tmp, f"manifest.p{self.proc}.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if not os.path.exists(final):
            os.replace(tmp, final)
        else:
            shutil.rmtree(tmp, ignore_errors=True)
        self._gc()

    def _write_shard(self, path: str, fname: str, flat) -> None:
        last: Optional[BaseException] = None
        for attempt in range(self.WRITE_RETRIES + 1):
            try:
                if faults.inject("ckpt.write", key=fname) is not None:
                    raise OSError(f"injected shard-write failure ({fname})")
                np.savez(path, **flat)
                return
            except OSError as e:
                last = e
                obs.audit().event("ckpt.write_retry", file=fname,
                                  attempt=attempt + 1, error=repr(e)[:120])
                obs.metrics().counter("ckpt_write_retries")
        raise last

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is None:
            return
        err, self._error = self._error, None
        self.n_write_failures += 1
        if self.on_error == "degrade":
            obs.audit().event("ckpt.write_failed", error=repr(err)[:200])
            obs.metrics().counter("ckpt_write_failures")
            return
        raise RuntimeError(f"async checkpoint write failed: {err!r}")

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except (ValueError, IndexError):
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, templates: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None,
                fallback: bool = True):
        """Rebuild trees shaped like ``templates``; optional ``shardings``
        (same structure) re-place arrays on a *new* mesh (elastic restore).

        When the requested checkpoint is unreadable (corrupt shard,
        truncated manifest, missing file) and ``fallback`` is True, each
        older ``step_N`` directory is tried in turn — losing the newest
        restore point beats losing the job.  The corruption is audited
        with the offending shard named; only when *no* checkpoint is
        readable does the original error surface."""
        candidates = [step]
        if fallback:
            candidates += [s for s in reversed(self.all_steps()) if s < step]
        first_err: Optional[BaseException] = None
        for s in candidates:
            try:
                return self._restore_one(s, templates, shardings)
            except (OSError, KeyError, ValueError) as e:
                if first_err is None:
                    first_err = e
                obs.audit().event("ckpt.restore_failed", step=s,
                                  error=repr(e)[:200])
                obs.metrics().counter("ckpt_restore_failures")
                if s != candidates[-1]:
                    self.n_restore_fallbacks += 1
                    obs.audit().event("ckpt.restore_fallback", frm=s)
        raise first_err

    def _restore_one(self, step: int, templates: Dict[str, Any],
                     shardings: Optional[Dict[str, Any]] = None):
        d = os.path.join(self.dir, f"step_{step:08d}")
        mpath = os.path.join(d, f"manifest.p{self.proc}.json")
        with open(mpath) as f:
            manifest = json.load(f)
        out = {}
        for name, template in templates.items():
            if template is None:
                out[name] = None
                continue
            info = manifest["trees"][name]
            path = os.path.join(d, info["file"])
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != info["sha256"]:
                raise IOError(
                    f"checkpoint corruption in shard {info['file']} of "
                    f"step {step}: sha256 {digest[:12]} != manifest "
                    f"{info['sha256'][:12]} ({path})")
            flat = dict(np.load(path))
            leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
            sh_leaves = None
            if shardings and shardings.get(name) is not None:
                sh_leaves = jax.tree_util.tree_leaves(
                    shardings[name],
                    is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            vals = []
            for i, (pathk, leaf) in enumerate(leaves_p):
                key = "/".join(
                    str(getattr(p, "key",
                                getattr(p, "name", getattr(p, "idx", p))))
                    for p in pathk)
                arr = flat[key]
                want = getattr(leaf, "dtype", None)
                if want is not None and arr.dtype != want:
                    arr = arr.astype(want)   # undo the bf16->f32 widening
                if sh_leaves is not None:
                    arr = jax.device_put(arr, sh_leaves[i])
                else:
                    arr = jax.device_put(arr)
                vals.append(arr)
            out[name] = jax.tree_util.tree_unflatten(treedef, vals)
        return out, manifest["extra"]
